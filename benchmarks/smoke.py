"""Bench smoke: recompute deterministic counters, diff vs checked-in JSON.

``PYTHONPATH=src python -m benchmarks.run --smoke``

The sharded and granularity benchmarks' counters are pure functions of the
schedule — graph, seeds, launch shape, shard count, chunk width — with
zero timing noise, so any change to the drain engines that shifts them is
a real behavioral regression, not jitter.  This re-runs the exact
configurations ``bench_shard`` records in ``BENCH_shard.json`` (BFS over
the R-MAT and grid graphs, every shard count, steal on/off, the 2-D mesh
sweep's per-axis exchange / overlap / compression counters, and the
grid-vs-successive-halving autotune agreement record) and
``bench_granularity`` records in ``BENCH_granularity.json`` (PageRank
ample/tight-budget rounds + formation splits and sharded per-g exchange
volume, every chunk width) and ``bench_stream`` records in
``BENCH_stream.json`` (per-delta-batch rounds/work/seed counts for the
incremental and full-recompute streaming modes, plus the sharded streaming
parity bit) and ``bench_megakernel`` records in ``BENCH_megakernel.json``
(rounds / launches-per-drain / work for every algorithm x kernel-strategy
cell — the megakernel's launches == 1 collapse and its bit-parity with the
persistent drain) and ``bench_obs`` records in ``BENCH_obs.json`` (per
policy cell: the tracing-disabled-is-identity parity bit, the round count
and the one-ring-record-per-round invariant) and fails loudly when any
recomputed counter disagrees with the checked-in value.  CI runs it on
every push (``bench-smoke`` job); the full benchmark suite refreshes the
JSONs deliberately, this guard keeps them honest in between.

The guard also validates every emitted artifact against the canonical
observability schema (``repro/obs/schema.py``): each ``BENCH_*.json``
must carry the ``meta`` provenance envelope (``validate_bench``), the
checked-in Chrome trace must be loadable trace-event JSON
(``validate_chrome_trace``) and the metrics JSONL must contain only
schema-valid documents (``validate_metrics_jsonl``).

Like the benchmarks, the measurement runs in a subprocess that forces 8
host devices before jax initializes, so the smoke works under plain CPU CI.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SHARD_JSON = REPO / "BENCH_shard.json"
GRANULARITY_JSON = REPO / "BENCH_granularity.json"
STREAM_JSON = REPO / "BENCH_stream.json"
MEGAKERNEL_JSON = REPO / "BENCH_megakernel.json"
OBS_JSON = REPO / "BENCH_obs.json"
OBS_TRACE_JSON = REPO / "BENCH_obs_trace.json"
OBS_METRICS_JSONL = REPO / "BENCH_obs_metrics.jsonl"

#: fields of each per-shard-count entry that are schedule-deterministic
#: (wall_seconds, balances etc. are measurements, not invariants)
_SHARD_FIELDS = ("rounds", "exchanged_total", "per_device_items")
_STEAL_FIELDS = ("rounds", "donated", "stolen_executed")
#: schedule-deterministic fields of each 2-D mesh cell (section 16):
#: per-axis cross-device payload, payload vs padding split, metered wire
#: ints, and the overlap pipeline's delivery counters
_MESH_FIELDS = ("rounds", "exchanged_total", "exchanged_row",
                "exchanged_col", "payload_ints", "padding_ints",
                "wire_ints", "deferred", "overlap_rounds")
#: the autotune agreement record is deterministic end to end (structural
#: runner, CRC tiebreak): the chosen keys themselves are pinned
_AUTOTUNE_FIELDS = ("grid_chosen", "sh_chosen", "agree", "cells_total",
                    "cells_measured")
#: schedule-deterministic fields of each granularity cell's workloads
_GRAN_FIELDS = {
    "pagerank_ample": ("rounds", "work", "splits"),
    "pagerank_tight": ("rounds", "work", "splits"),
    "bfs_shard": ("rounds", "exchanged_total", "splits"),
}
#: schedule-deterministic fields of each streaming per-batch record
#: (touched/overlay/compacted meter the slotted O(delta) commit path —
#: pure functions of the delta log + COMPACT_EVERY, so guarded too)
_STREAM_FIELDS = ("rounds", "work", "seeds", "eff", "touched", "overlay",
                  "compacted")
_STREAM_SHARD_FIELDS = ("rounds", "work", "exchanged", "parity")
#: schedule-deterministic fields of each (algorithm x kernel) cell —
#: launches is the megakernel's headline invariant (1 per drain)
_MEGA_FIELDS = ("rounds", "launches", "work")
#: schedule-deterministic fields of each obs policy cell — parity is the
#: tracing-disabled-is-identity invariant, ring_records the
#: one-record-per-round invariant (walls/ratios are measurements)
_OBS_FIELDS = ("rounds", "work", "ring_records", "parity")


def _recompute() -> dict:
    """Run bench_shard's deterministic portion in an 8-device subprocess.

    Every graph parameter and launch shape is imported from bench_shard so
    the guard can never drift from the configs that produced the baseline.
    """
    from .bench_shard import (GRID_SIDE, MESH_SHAPES, SCALE, SHARD_COUNTS,
                              SHARD_WORKERS, STEAL_CHUNK, STEAL_THRESHOLD,
                              STEAL_WORKERS)

    body = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
import json
import numpy as np
from repro.core import SchedulerConfig
from repro.graph.generators import grid2d, rmat
from repro.runtime import build_program
from repro.shard import run_sharded

graphs = {{
    'rmat': rmat({SCALE}, edge_factor=8, seed=1),
    'grid': grid2d({GRID_SIDE}, {GRID_SIDE}, seed=0),
}}
out = {{}}
for name, g in graphs.items():
    entry = {{'shards': {{}}, 'steal': {{}}}}
    for s in {list(SHARD_COUNTS)}:
        cfg = SchedulerConfig(num_workers={SHARD_WORKERS}, fetch_size=1,
                              num_shards=s, persistent=False)
        program = build_program('bfs', g, cfg, params={{'source': 0}})
        state, stats = run_sharded(program, g, cfg)
        entry['shards'][str(s)] = {{
            'rounds': stats.rounds,
            'exchanged_total': stats.exchanged,
            'per_device_items': stats.per_device_items.tolist(),
        }}
    for label, kw in {{'steal_off': {{}},
                       'steal_on': {{'steal_threshold': {STEAL_THRESHOLD},
                                     'steal_chunk': {STEAL_CHUNK}}}}}.items():
        cfg = SchedulerConfig(num_workers={STEAL_WORKERS}, num_shards=8,
                              persistent=False, **kw)
        program = build_program('bfs', g, cfg, params={{'source': 0}})
        state, stats = run_sharded(program, g, cfg)
        entry['steal'][label] = {{
            'rounds': stats.rounds,
            'donated': stats.donated,
            'stolen_executed': stats.stolen_executed,
        }}
    if name == 'rmat':
        entry['mesh'] = {{}}
        for mesh in {list(MESH_SHAPES)}:
            label = '%dx%d' % tuple(mesh)
            entry['mesh'][label] = {{}}
            for dlabel, defer in (('strict', 0), ('defer', 1)):
                cell = {{}}
                for clabel, comp in (('raw', False), ('compressed', True)):
                    cfg = SchedulerConfig(num_workers={SHARD_WORKERS},
                                          num_shards=8,
                                          mesh_shape=tuple(mesh),
                                          defer_rounds=defer, compress=comp)
                    program = build_program('bfs', g, cfg,
                                            params={{'source': 0}})
                    state, stats = run_sharded(program, g, cfg)
                    cell[clabel] = {{
                        'rounds': stats.rounds,
                        'exchanged_total': stats.exchanged,
                        'exchanged_row': stats.exchanged_row,
                        'exchanged_col': stats.exchanged_col,
                        'payload_ints': stats.payload_ints,
                        'padding_ints': stats.padding_ints,
                        'wire_ints': stats.wire_ints,
                        'deferred': stats.deferred_delivered,
                        'overlap_rounds': stats.overlap_rounds,
                    }}
                entry['mesh'][label][dlabel] = cell
    import tempfile
    from pathlib import Path as _P
    from repro.server import Autotuner, structural_cost_runner
    with tempfile.TemporaryDirectory() as td:
        Autotuner(cache_path=_P(td) / 'g.json', warmup=0, iters=1,
                  runner=structural_cost_runner,
                  search='grid').tune('bfs', g)
        Autotuner(cache_path=_P(td) / 's.json', warmup=0, iters=1,
                  runner=structural_cost_runner, search='sh').tune('bfs', g)
        ge = next(iter(json.loads((_P(td) / 'g.json').read_text()).values()))
        se = next(iter(json.loads((_P(td) / 's.json').read_text()).values()))
    entry['autotune'] = {{
        'grid_chosen': ge['chosen'], 'sh_chosen': se['chosen'],
        'agree': ge['chosen'] == se['chosen'],
        'cells_total': se['cells_total'],
        'cells_measured': se['cells_measured'],
    }}
    out[name] = entry
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [str(REPO / "src")] + ([os.environ["PYTHONPATH"]]
                               if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, env=env, timeout=1800, cwd=REPO)
    if proc.returncode != 0:
        raise RuntimeError(f"smoke subprocess failed:\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _recompute_granularity() -> dict:
    """Re-run bench_granularity's deterministic portion (8-device child).

    Imports the sweep constants from bench_granularity so the guard can
    never drift from the configs that produced the baseline.
    """
    from .bench_granularity import (GRANULARITIES, GRID_SIDE, PR_EPS,
                                    PR_WORKERS, SCALE, SHARD_WORKERS,
                                    TIGHT_BUDGET)

    body = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
import json
import numpy as np
from repro.algorithms.pagerank import pagerank_async
from repro.core import SchedulerConfig
from repro.graph.generators import grid2d, rmat
from repro.runtime import build_program
from repro.shard import run_sharded

graphs = {{
    'rmat': rmat({SCALE}, edge_factor=8, seed=1),
    'grid': grid2d({GRID_SIDE}, {GRID_SIDE}, seed=0),
}}
out = {{}}
for name, g in graphs.items():
    entry = {{}}
    for gr in {list(GRANULARITIES)}:
        cell = {{}}
        for label, budget in (('pagerank_ample', None),
                              ('pagerank_tight', {TIGHT_BUDGET})):
            cfg = SchedulerConfig(num_workers={PR_WORKERS}, fetch_size=1,
                                  persistent=False, granularity=gr)
            _, info = pagerank_async(g, cfg, eps={PR_EPS},
                                     work_budget=budget)
            cell[label] = {{'rounds': info['rounds'], 'work': info['work'],
                            'splits': info['splits']}}
        cfg = SchedulerConfig(num_workers={SHARD_WORKERS}, fetch_size=1,
                              num_shards=8, persistent=False,
                              granularity=gr)
        program = build_program('bfs', g, cfg, params={{'source': 0}})
        state, stats = run_sharded(program, g, cfg)
        cell['bfs_shard'] = {{'rounds': stats.rounds,
                              'exchanged_total': stats.exchanged,
                              'splits': program.splits_of(state)}}
        entry[str(gr)] = cell
    out[name] = entry
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [str(REPO / "src")] + ([os.environ["PYTHONPATH"]]
                               if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, env=env, timeout=1800, cwd=REPO)
    if proc.returncode != 0:
        raise RuntimeError(
            f"granularity smoke subprocess failed:\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _recompute_stream() -> dict:
    """Re-run bench_stream's deterministic portion (8-device child).

    Imports the stream constants from bench_stream so the guard can never
    drift from the configs that produced the baseline.
    """
    from .bench_stream import (ALGOS, BATCH_SIZE, BATCHES, COMPACT_EVERY,
                               EDGE_FACTOR, GRAPH_SEED, SCALE, STREAM_SEED,
                               WORKERS)

    body = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
import json
import numpy as np
from repro.core import SchedulerConfig
from repro.graph.generators import edge_delta_stream, rmat
from repro.runtime import stream_execute

base = rmat({SCALE}, edge_factor={EDGE_FACTOR}, seed={GRAPH_SEED})
deltas = edge_delta_stream(base, {BATCHES}, {BATCH_SIZE},
                           seed={STREAM_SEED})
cfg = SchedulerConfig(num_workers={WORKERS}, topology='single',
                      persistent=False)
out = {{'algorithms': {{}}, 'm': base.num_edges}}
for algo, params in {list(ALGOS)!r}:
    entry = {{}}
    for mode, incr in (('incremental', True), ('full', False)):
        res = stream_execute(algo, base, deltas, cfg, params=dict(params),
                             incremental=incr,
                             compact_every={COMPACT_EVERY})
        entry[mode] = [{{'rounds': r.rounds, 'work': r.work,
                         'seeds': r.seeds, 'eff': r.effective_ops,
                         'touched': r.touched_rows, 'overlay': r.overlay,
                         'compacted': r.compacted}}
                       for r in res.batches]
    out['algorithms'][algo] = entry
scfg = SchedulerConfig(num_workers={WORKERS}, topology='sharded',
                       num_shards=8, persistent=False)
sres = stream_execute('bfs', base, deltas, scfg, params={{'source': 0}},
                      compact_every={COMPACT_EVERY})
ref = stream_execute('bfs', base, deltas, cfg, params={{'source': 0}},
                     compact_every={COMPACT_EVERY})
out['sharded_bfs'] = {{
    'rounds': sres.info['rounds'], 'work': sres.info['work'],
    'exchanged': sres.info['exchanged'],
    'parity': bool((np.asarray(sres.result)
                    == np.asarray(ref.result)).all()),
}}
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [str(REPO / "src")] + ([os.environ["PYTHONPATH"]]
                               if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, env=env, timeout=1800, cwd=REPO)
    if proc.returncode != 0:
        raise RuntimeError(
            f"stream smoke subprocess failed:\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _recompute_megakernel() -> dict:
    """Re-run bench_megakernel's deterministic portion in a subprocess.

    Imports the sweep constants from bench_megakernel so the guard can
    never drift from the configs that produced the baseline.
    """
    from .bench_megakernel import (ALGOS, EDGE_FACTOR, GRAPH_SEED, KERNELS,
                                   SCALE, WORKERS)

    body = f"""
import os
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
import json
import numpy as np
from repro.core import SchedulerConfig
from repro.graph.generators import rmat
from repro.runtime import (ExecutionPolicy, build_program, config_for,
                           execute)

g = rmat({SCALE}, edge_factor={EDGE_FACTOR}, seed={GRAPH_SEED})
out = {{'algorithms': {{}}}}
for algo, params in {list(ALGOS)!r}:
    entry = {{}}
    results = {{}}
    for kernel in {list(KERNELS)}:
        cfg = config_for(SchedulerConfig(num_workers={WORKERS}),
                         ExecutionPolicy('single', kernel))
        program = build_program(algo, g, cfg, params=dict(params))
        state, stats, info = execute(program, g, cfg)
        results[kernel] = np.asarray(program.result(state))
        entry[kernel] = {{'rounds': info['rounds'],
                          'launches': info['launches'],
                          'work': info['work']}}
    entry['parity_vs_persistent'] = bool(
        (results['megakernel'] == results['persistent']).all())
    out['algorithms'][algo] = entry
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [str(REPO / "src")] + ([os.environ["PYTHONPATH"]]
                               if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, env=env, timeout=1800, cwd=REPO)
    if proc.returncode != 0:
        raise RuntimeError(
            f"megakernel smoke subprocess failed:\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _recompute_obs() -> dict:
    """Re-run bench_obs's deterministic portion in a subprocess.

    Recomputes, per policy cell, the traced-vs-untraced parity bit, the
    round count and the ring record count — the walls/ratios in the
    checked-in JSON are measurements and are not guarded.
    """
    from .bench_obs import CELLS, EDGE_FACTOR, GRAPH_SEED, SCALE, WORKERS

    body = f"""
import os
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
import json
import numpy as np
from repro.core import SchedulerConfig
from repro.graph.generators import rmat
from repro.obs import Trace
from repro.runtime import build_program, config_for, execute, parse_policy

g = rmat({SCALE}, edge_factor={EDGE_FACTOR}, seed={GRAPH_SEED})
out = {{'cells': {{}}}}
for cell in {list(CELLS)!r}:
    policy = parse_policy(cell)
    cfg = config_for(SchedulerConfig(num_workers={WORKERS}), policy)
    program = build_program('bfs', g, cfg, params={{'source': 0}})
    base_state, base_stats, base_info = execute(program, g, cfg)
    trace = Trace()
    tr_state, tr_stats, tr_info = execute(program, g, cfg, trace=trace)
    out['cells'][cell] = {{
        'rounds': base_info['rounds'],
        'work': base_info['work'],
        'ring_records': len(trace.records),
        'parity': bool(
            (np.asarray(program.result(tr_state))
             == np.asarray(program.result(base_state))).all()
            and tr_info == base_info),
    }}
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [str(REPO / "src")] + ([os.environ["PYTHONPATH"]]
                               if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, env=env, timeout=1800, cwd=REPO)
    if proc.returncode != 0:
        raise RuntimeError(
            f"obs smoke subprocess failed:\n{proc.stderr[-3000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def validate_artifacts() -> list:
    """Schema-validate every emitted artifact; returns a list of error
    strings (empty = pass).

    Every ``BENCH_*.json`` at the repo root must carry the canonical
    ``meta`` envelope (``obs.validate_bench``); the obs trace must be a
    loadable Chrome trace-event document and the obs metrics JSONL must
    contain only schema-valid docs.  Runs in-process — validation needs
    no jax and no devices.
    """
    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.obs import (validate_bench, validate_chrome_trace,
                               validate_metrics_jsonl)
    finally:
        sys.path.pop(0)

    errors = []
    for path in sorted(REPO.glob("BENCH_*.json")):
        if path.name == OBS_TRACE_JSON.name:
            continue          # chrome-trace format, validated below
        try:
            validate_bench(json.loads(path.read_text()), name=path.name)
        except ValueError as e:
            errors.append(str(e))
    if OBS_TRACE_JSON.exists():
        try:
            validate_chrome_trace(json.loads(OBS_TRACE_JSON.read_text()))
        except ValueError as e:
            errors.append(f"{OBS_TRACE_JSON.name}: {e}")
    if OBS_METRICS_JSONL.exists():
        try:
            validate_metrics_jsonl(
                OBS_METRICS_JSONL.read_text().splitlines())
        except ValueError as e:
            errors.append(f"{OBS_METRICS_JSONL.name}: {e}")
    return errors


def run() -> int:
    """Returns the number of mismatches (0 = pass); prints a report."""
    missing = [p for p in (SHARD_JSON, GRANULARITY_JSON, STREAM_JSON,
                           MEGAKERNEL_JSON, OBS_JSON)
               if not p.exists()]
    if missing:
        for p in missing:
            section = {SHARD_JSON: "shard",
                       GRANULARITY_JSON: "granularity",
                       STREAM_JSON: "stream",
                       MEGAKERNEL_JSON: "megakernel",
                       OBS_JSON: "obs"}[p]
            print(f"smoke: {p.name} missing — run "
                  f"'python -m benchmarks.run {section}' to create the "
                  f"baseline")
        return 1
    mismatches = 0

    def check(path: str, want, got):
        nonlocal mismatches
        if want != got:
            mismatches += 1
            print(f"smoke MISMATCH {path}: checked-in {want!r} != "
                  f"recomputed {got!r}")

    baseline = json.loads(SHARD_JSON.read_text())["graphs"]
    fresh = _recompute()
    for gname, entry in baseline.items():
        for s, want in entry["shards"].items():
            got = fresh[gname]["shards"][s]
            for field in _SHARD_FIELDS:
                check(f"{gname}/shards={s}/{field}", want[field], got[field])
        for label, want in entry.get("steal", {}).items():
            got = fresh[gname]["steal"][label]
            for field in _STEAL_FIELDS:
                check(f"{gname}/steal/{label}/{field}", want[field],
                      got[field])
        for label, modes in entry.get("mesh", {}).items():
            for dlabel, want_cell in modes.items():
                for clabel in ("raw", "compressed"):
                    got_cell = fresh[gname]["mesh"][label][dlabel][clabel]
                    for field in _MESH_FIELDS:
                        check(f"{gname}/mesh{label}/{dlabel}/{clabel}"
                              f"/{field}", want_cell[clabel][field],
                              got_cell[field])
        if "autotune" in entry:
            got_at = fresh[gname]["autotune"]
            for field in _AUTOTUNE_FIELDS:
                check(f"{gname}/autotune/{field}",
                      entry["autotune"][field], got_at[field])

    gran_base = json.loads(GRANULARITY_JSON.read_text())["graphs"]
    gran_fresh = _recompute_granularity()
    for gname, entry in gran_base.items():
        for gr, cell in entry["g"].items():
            got_cell = gran_fresh[gname][gr]
            for workload, fields in _GRAN_FIELDS.items():
                for field in fields:
                    check(f"{gname}/g={gr}/{workload}/{field}",
                          cell[workload][field],
                          got_cell[workload][field])

    stream_base = json.loads(STREAM_JSON.read_text())
    stream_fresh = _recompute_stream()
    stream_m = stream_fresh["m"]
    for algo, entry in stream_base["algorithms"].items():
        for mode in ("incremental", "full"):
            want_rows = entry[mode]["per_batch"]
            got_rows = stream_fresh["algorithms"][algo][mode]
            for i, (want, got) in enumerate(zip(want_rows, got_rows)):
                for field in _STREAM_FIELDS:
                    check(f"stream/{algo}/{mode}/batch{i}/{field}",
                          want[field], got[field])
                # O(delta) commit guard: a commit rewriting >= m rows
                # means the slotted path degraded to a full rebuild
                check(f"stream/{algo}/{mode}/batch{i}/touched<m",
                      True, got["touched"] < stream_m)
    for field in _STREAM_SHARD_FIELDS:
        check(f"stream/sharded_bfs/{field}",
              stream_base["sharded_bfs"][field],
              stream_fresh["sharded_bfs"][field])

    mega_base = json.loads(MEGAKERNEL_JSON.read_text())["algorithms"]
    mega_fresh = _recompute_megakernel()["algorithms"]
    from .bench_megakernel import KERNELS as _MEGA_KERNELS
    for algo, entry in mega_base.items():
        for kernel in _MEGA_KERNELS:
            for field in _MEGA_FIELDS:
                check(f"megakernel/{algo}/{kernel}/{field}",
                      entry[kernel][field],
                      mega_fresh[algo][kernel][field])
        check(f"megakernel/{algo}/parity_vs_persistent",
              entry["parity_vs_persistent"],
              mega_fresh[algo]["parity_vs_persistent"])

    obs_base = json.loads(OBS_JSON.read_text())["cells"]
    obs_fresh = _recompute_obs()["cells"]
    for cell, entry in obs_base.items():
        for field in _OBS_FIELDS:
            check(f"obs/{cell}/{field}", entry[field],
                  obs_fresh[cell][field])

    for err in validate_artifacts():
        mismatches += 1
        print(f"smoke SCHEMA {err}")

    names = (f"{SHARD_JSON.name} / {GRANULARITY_JSON.name} / "
             f"{STREAM_JSON.name} / {MEGAKERNEL_JSON.name} / "
             f"{OBS_JSON.name} + artifact schemas")
    if mismatches:
        print(f"smoke: {mismatches} counter regression(s) vs {names}")
    else:
        print(f"smoke: OK — all deterministic counters match {names}")
    return mismatches


def main() -> None:
    sys.exit(1 if run() else 0)


if __name__ == "__main__":
    main()
