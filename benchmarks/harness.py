"""Shared benchmark utilities: timed runs + CSV/JSON emission.

Every ``BENCH_*.json`` document carries a ``meta`` provenance block
(git sha, jax version, device kind, python, schema version) stamped by
:func:`bench_meta` and is written atomically (temp-then-rename) so a
crashed or interrupted benchmark can never leave a truncated artifact
behind; ``benchmarks/smoke.py`` validates every emitted document against
the canonical schema in ``repro/obs/schema.py``.
"""
from __future__ import annotations

import json
import statistics
import subprocess
import time
from pathlib import Path
from typing import Callable

import jax


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over ``iters`` runs (after ``warmup``)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit_host(fn: Callable, *, warmup: int = 1, iters: int = 3):
    """Like ``timeit`` but for host-driven loops whose return value matters:
    returns (median wall seconds, last result)."""
    result = None
    for _ in range(warmup):
        result = fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        result = fn()
        times.append(time.perf_counter() - t0)
    return statistics.median(times), result


def bench_meta() -> dict:
    """Provenance block stamped into every BENCH_*.json (obs/schema.py)."""
    import platform

    from repro.obs import SCHEMA_VERSION

    try:
        git_sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, check=True).stdout.strip()
    except Exception:
        git_sha = "unknown"
    try:
        device_kind = str(jax.devices()[0].device_kind)
    except Exception:
        device_kind = "unknown"
    return {
        "git_sha": git_sha,
        "jax_version": jax.__version__,
        "device_kind": device_kind,
        "python": platform.python_version(),
        "schema": SCHEMA_VERSION,
    }


def emit_json(path: str | Path, payload: dict) -> Path:
    """Atomically write a benchmark result document with its ``meta``
    provenance block; returns the path written.

    temp-then-rename so a crash mid-write never leaves a truncated
    ``BENCH_*.json`` behind (os.replace is atomic on POSIX)."""
    from repro.obs import atomic_write_text

    path = Path(path)
    payload = dict(payload)
    payload.setdefault("meta", bench_meta())
    atomic_write_text(path, json.dumps(payload, indent=2, sort_keys=True)
                      + "\n")
    print(f"wrote {path}")
    return path
