"""Shared benchmark utilities: timed runs + CSV/JSON emission."""
from __future__ import annotations

import json
import statistics
import time
from pathlib import Path
from typing import Callable

import jax


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over ``iters`` runs (after ``warmup``)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit_host(fn: Callable, *, warmup: int = 1, iters: int = 3):
    """Like ``timeit`` but for host-driven loops whose return value matters:
    returns (median wall seconds, last result)."""
    result = None
    for _ in range(warmup):
        result = fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        result = fn()
        times.append(time.perf_counter() - t0)
    return statistics.median(times), result


def emit_json(path: str | Path, payload: dict) -> Path:
    """Write a benchmark result document; returns the path written."""
    path = Path(path)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}")
    return path
