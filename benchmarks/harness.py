"""Shared benchmark utilities: timed runs + CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over ``iters`` runs (after ``warmup``)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
