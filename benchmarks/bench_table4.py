"""Paper Table 4 analogue: workload (overwork) ratios.

Upper block: BFS + PageRank work relative to the BSP implementation's work.
Lower block: graph-coloring work relative to |V| (the minimum possible),
including the BSP variant — exactly the paper's normalization.

CSV: name, ratio*1000 (us column reused), derived = "ratio=<r>".
"""
from __future__ import annotations

import numpy as np

from repro.algorithms.bfs import bfs_bsp, bfs_speculative
from repro.algorithms.coloring import coloring_async, coloring_bsp
from repro.algorithms.pagerank import pagerank_async, pagerank_bsp
from repro.core import SchedulerConfig
from repro.graph import grid2d, rmat

from .harness import row

DATASETS = {
    "scale_free": lambda: rmat(9, 8, seed=1),
    "mesh_like": lambda: grid2d(32, 32),
}


def run():
    for dname, make in DATASETS.items():
        g = make()
        n = g.num_vertices
        cfgP = SchedulerConfig(num_workers=16, fetch_size=4, persistent=True,
                               max_rounds=1 << 20)
        cfgW = SchedulerConfig(num_workers=64, fetch_size=1, persistent=True,
                               max_rounds=1 << 20)

        # BFS: vertices processed / vertices reached (BSP processes each once)
        dist, _ = bfs_bsp(g, 0)
        reached = int((np.asarray(dist) < 0x7FFFFFFF).sum())
        for vname, strat, cfg in [("persist-warp", "per_item", cfgW),
                                  ("persist-CTA", "merge_path", cfgP)]:
            _, info = bfs_speculative(g, 0, cfg, strategy=strat)
            r = info["work"] / reached
            row(f"table4/bfs/{dname}/{vname}", r * 1000, f"ratio={r:.3f}")

        # PageRank: async work / BSP work (paper: <1 on scale-free)
        _, info_b = pagerank_bsp(g, eps=1e-6)
        _, info_a = pagerank_async(g, cfgP, eps=1e-6)
        r = info_a["work"] / max(info_b["work"], 1)
        row(f"table4/pagerank/{dname}/persist-CTA", r * 1000,
            f"ratio={r:.3f}")

        # Coloring: work / |V| for BSP and async (paper's lower block)
        _, info_b = coloring_bsp(g)
        row(f"table4/coloring/{dname}/BSP", info_b['work'] / n * 1000,
            f"ratio={info_b['work'] / n:.3f}")
        _, info_a = coloring_async(g, cfgP)
        row(f"table4/coloring/{dname}/persist-CTA",
            info_a["work"] / n * 1000, f"ratio={info_a['work'] / n:.3f}")
