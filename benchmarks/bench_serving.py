"""Serving benchmark: Atos continuous batching vs BSP batch serving.

The LM-framework incarnation of the paper's claim — relaxed barriers raise
occupancy/throughput when task sizes (output lengths) are skewed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import ContinuousBatchingEngine, Request

from .harness import row, timeit


def run():
    cfg = smoke_config("stablelm-1.6b")
    params = init_params(T.model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=[int(rng.integers(1, cfg.vocab_size))],
                    max_new_tokens=int(rng.choice([2, 2, 2, 12])))
            for i in range(12)]
    for mode in ["bsp", "continuous"]:
        eng = ContinuousBatchingEngine(cfg, params, num_slots=4, max_len=32,
                                       mode=mode)
        res = eng.run(list(reqs))
        st = res["stats"]
        total = sum(len(v) for v in res["outputs"].values())
        row(f"serving/{mode}", st.wavefronts * 1000,
            f"wavefronts={st.wavefronts};occupancy={st.mean_occupancy:.3f};"
            f"tokens={total}")
