"""Paper Table 1 analogue: runtime of BSP vs three Atos variants on the
three case studies x {scale-free, mesh-like} synthetic datasets.

Variants mirror the paper's:
  persist-warp : persistent scheduler, per-item expansion (task-LB only)
  persist-CTA  : persistent scheduler, merge-path expansion (task+data LB)
  discrete-CTA : discrete scheduler, merge-path expansion

CSV columns: name, us_per_call, derived (speedup vs BSP).
"""
from __future__ import annotations

import numpy as np

from repro.algorithms.bfs import bfs_bsp, bfs_speculative
from repro.algorithms.coloring import coloring_async, coloring_bsp
from repro.algorithms.pagerank import pagerank_async, pagerank_bsp
from repro.core import SchedulerConfig
from repro.graph import grid2d, rmat

from .harness import row, timeit

DATASETS = {
    "scale_free": lambda: rmat(9, 8, seed=1),
    "mesh_like": lambda: grid2d(32, 32),
}

VARIANTS = {
    "persist-warp": dict(persistent=True, strategy="per_item"),
    "persist-CTA": dict(persistent=True, strategy="merge_path"),
    "discrete-CTA": dict(persistent=False, strategy="merge_path"),
}


def _cfg(persistent):
    return SchedulerConfig(num_workers=16, fetch_size=4,
                           persistent=persistent, max_rounds=1 << 20)


def run():
    for dname, make in DATASETS.items():
        g = make()
        # ---- BFS
        t_bsp = timeit(lambda: bfs_bsp(g, 0)[0])
        row(f"table1/bfs/{dname}/BSP", t_bsp * 1e6, "x1.00")
        for vname, v in VARIANTS.items():
            t = timeit(lambda: bfs_speculative(
                g, 0, _cfg(v["persistent"]), strategy=v["strategy"])[0])
            row(f"table1/bfs/{dname}/{vname}", t * 1e6,
                f"x{t_bsp / t:.2f}")
        # ---- PageRank
        t_bsp = timeit(lambda: pagerank_bsp(g, eps=1e-6)[0])
        row(f"table1/pagerank/{dname}/BSP", t_bsp * 1e6, "x1.00")
        for vname, v in VARIANTS.items():
            if v["strategy"] == "per_item":
                continue  # pagerank push uses merge-path expansion only
            t = timeit(lambda: pagerank_async(
                g, _cfg(v["persistent"]), eps=1e-6)[0])
            row(f"table1/pagerank/{dname}/{vname}", t * 1e6,
                f"x{t_bsp / t:.2f}")
        # ---- Graph coloring
        t_bsp = timeit(lambda: coloring_bsp(g)[0])
        row(f"table1/coloring/{dname}/BSP", t_bsp * 1e6, "x1.00")
        for vname, v in VARIANTS.items():
            if v["strategy"] == "per_item" and vname != "persist-warp":
                continue
            t = timeit(lambda: coloring_async(g, _cfg(v["persistent"]))[0])
            row(f"table1/coloring/{dname}/{vname}", t * 1e6,
                f"x{t_bsp / t:.2f}")
