"""Task-granularity benchmark: the paper's third scheduling dial, measured.

  PYTHONPATH=src python -m benchmarks.run granularity

Sweeps chunk width g ∈ {1, 2, 4, 8} x execution policy over the paper's
two graph regimes (R-MAT scale-free vs 2-D mesh) and emits
``BENCH_granularity.json`` with, per (graph, g):

  * ``pagerank_ample``  — async PageRank, default (ample) merge-path work
    budget, ``single.discrete.g<g>``: rounds / work / splits.  The mesh
    regime's headline: the dense seed frontier and the rotating rescan ride
    in chunks, so coarse tasks cut rounds ~2x while degree uniformity keeps
    the overwork cost mild — *coarse tasks win on mesh-like graphs*.
  * ``pagerank_tight``  — same drain with the work budget pinned to the
    max-degree floor (the LBS capacity a hub already saturates): on the
    scale-free graph coarse chunks fight the budget — formation splits
    engage (the ``splits`` meter) and whole-chunk truncation re-queues
    inflate rounds, so *fine tasks + LBS win on power-law graphs*.  The
    g=1 row beats every coarser row in both rounds and work.
  * ``bfs_shard``       — sharded BFS over 8 devices,
    ``sharded.discrete.g<g>``: rounds / per-g exchange volume (chunked
    tasks ship fewer wire ints for the same routed vertices) / splits,
    with bit-identical distances asserted at every width.

All recorded counters are schedule-deterministic (pure functions of graph,
seeds, launch shape, width) — ``benchmarks/smoke.py`` recomputes them in CI
and fails on drift, exactly like the BENCH_shard.json guard.  Wall times
are recorded for context but excluded from the guard.  The crossover is
explained in DESIGN.md section 12.

The measurement runs in a subprocess that forces 8 XLA host devices before
jax initializes, so the benchmark works from any session.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .harness import emit_json, row

OUT = "BENCH_granularity.json"
GRANULARITIES = (1, 2, 4, 8)
SCALE = 8          # R-MAT: 2**8 vertices
GRID_SIDE = 16     # mesh: 16x16
# launch shapes shared with benchmarks/smoke.py — the regression guard must
# recompute with exactly the configs that produced the checked-in JSON
PR_WORKERS = 16        # single-device PageRank wavefront (slots)
PR_EPS = 1e-4
TIGHT_BUDGET = 128     # ~the max-degree floor of the scale-free graph
SHARD_WORKERS = 32     # per-device BFS wavefront over the 8-shard mesh


def _child() -> None:
    import time

    import numpy as np

    from repro.algorithms.bfs import bfs_bsp
    from repro.algorithms.pagerank import pagerank_async
    from repro.core import SchedulerConfig
    from repro.graph.generators import grid2d, rmat
    from repro.runtime import build_program
    from repro.shard import run_sharded

    graphs = {
        "rmat": rmat(SCALE, edge_factor=8, seed=1),
        "grid": grid2d(GRID_SIDE, GRID_SIDE, seed=0),
    }
    payload: dict = {"granularities": list(GRANULARITIES), "graphs": {}}
    for name, g in graphs.items():
        ref = np.asarray(bfs_bsp(g, 0)[0])
        entry: dict = {"n": g.num_vertices, "m": g.num_edges, "g": {}}
        for gr in GRANULARITIES:
            cell: dict = {}
            for label, budget in (("pagerank_ample", None),
                                  ("pagerank_tight", TIGHT_BUDGET)):
                cfg = SchedulerConfig(num_workers=PR_WORKERS, fetch_size=1,
                                      persistent=False, granularity=gr)
                t0 = time.perf_counter()
                _, info = pagerank_async(g, cfg, eps=PR_EPS,
                                         work_budget=budget)
                cell[label] = {
                    "rounds": info["rounds"],
                    "work": info["work"],
                    "splits": info["splits"],
                    "wall_seconds": time.perf_counter() - t0,
                }
            cfg = SchedulerConfig(num_workers=SHARD_WORKERS, fetch_size=1,
                                  num_shards=8, persistent=False,
                                  granularity=gr)
            program = build_program("bfs", g, cfg, params={"source": 0})
            t0 = time.perf_counter()
            state, stats = run_sharded(program, g, cfg)
            wall = time.perf_counter() - t0
            assert (np.asarray(state.dist) == ref).all(), (name, gr)
            assert stats.mis_routed == 0 and stats.dropped == 0, (name, gr)
            cell["bfs_shard"] = {
                "rounds": stats.rounds,
                "exchanged_total": stats.exchanged,
                "splits": program.splits_of(state),
                "wall_seconds": wall,
            }
            entry["g"][str(gr)] = cell
        payload["graphs"][name] = entry

    def best(graph, workload):
        cells = payload["graphs"][graph]["g"]
        return min(cells, key=lambda k: cells[k][workload]["rounds"])

    # the paper's granularity finding, pinned as data: coarse chunks win
    # the mesh regime, width-1 wins the budget-bound scale-free regime
    payload["findings"] = {
        "coarse_wins_mesh": {"graph": "grid", "workload": "pagerank_ample",
                             "best_g": best("grid", "pagerank_ample")},
        "fine_wins_scale_free": {"graph": "rmat",
                                 "workload": "pagerank_tight",
                                 "best_g": best("rmat", "pagerank_tight")},
    }
    print(json.dumps(payload))


def run(out: str = OUT):
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_granularity", "--child"],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_granularity child failed:\n{proc.stderr[-3000:]}")
    payload = json.loads(proc.stdout.strip().splitlines()[-1])

    for name, entry in payload["graphs"].items():
        for gr, cell in sorted(entry["g"].items(), key=lambda kv: int(kv[0])):
            a, t, s = (cell["pagerank_ample"], cell["pagerank_tight"],
                       cell["bfs_shard"])
            row(f"granularity/{name}/g{gr}",
                a["wall_seconds"] * 1e6,
                f"pr_rounds={a['rounds']} pr_tight_rounds={t['rounds']} "
                f"tight_splits={t['splits']} shard_rounds={s['rounds']} "
                f"exchanged={s['exchanged_total']}")
    f = payload["findings"]
    row("granularity/crossover", 0.0,
        f"mesh best_g={f['coarse_wins_mesh']['best_g']} "
        f"scale_free_tight best_g={f['fine_wins_scale_free']['best_g']}")
    emit_json(out, payload)
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run()
