"""Paper Figs 1-3 (throughput vs time) and Fig 4 (fetch x workers heatmap).

Fig 1-3: per-round trace from the discrete driver — (queue_size,
items_processed) per wavefront; normalized throughput = items/round divided
by the overwork factor, exactly the paper's normalization.  Emitted as CSV
rows (round, items) per algorithm/dataset; the derived field carries the
normalized mean throughput.

Fig 4: runtime heatmap over (num_workers x fetch_size) for BFS and PageRank
on both dataset classes — the paper's task/data-parallelism trade-off.
"""
from __future__ import annotations

import numpy as np

from repro.algorithms.bfs import bfs_bsp, bfs_speculative
from repro.algorithms.pagerank import pagerank_async
from repro.algorithms.coloring import coloring_async
from repro.core import SchedulerConfig
from repro.graph import grid2d, rmat

from .harness import row, timeit

DATASETS = {
    "scale_free": lambda: rmat(9, 8, seed=1),
    "mesh_like": lambda: grid2d(32, 32),
}


def run_figs123():
    for dname, make in DATASETS.items():
        g = make()
        cfg = SchedulerConfig(num_workers=16, fetch_size=4, persistent=False,
                              max_rounds=1 << 20)
        # BFS trace
        trace = []
        dist, info = bfs_speculative(g, 0, cfg, trace=trace)
        reached = int((np.asarray(dist) < 0x7FFFFFFF).sum())
        overwork = info["work"] / max(reached, 1)
        thr = [p for _, p in trace]
        row(f"fig1/bfs/{dname}", float(np.mean(thr)) * 1000,
            f"rounds={len(trace)};overwork={overwork:.2f};"
            f"norm_thr={np.mean(thr) / overwork:.1f}")
        # PageRank trace
        trace = []
        _, info = pagerank_async(g, cfg, eps=1e-6, trace=trace)
        thr = [p for _, p in trace]
        row(f"fig2/pagerank/{dname}", float(np.mean(thr)) * 1000,
            f"rounds={len(trace)};norm_thr={np.mean(thr):.1f}")
        # Coloring trace
        trace = []
        _, info = coloring_async(g, cfg, trace=trace)
        overwork = info["work"] / g.num_vertices
        thr = [p for _, p in trace]
        row(f"fig3/coloring/{dname}", float(np.mean(thr)) * 1000,
            f"rounds={len(trace)};overwork={overwork:.2f};"
            f"norm_thr={np.mean(thr) / overwork:.1f}")


def run_fig4():
    for dname, make in DATASETS.items():
        g = make()
        for workers in [4, 16, 64]:
            for fetch in [1, 4, 16]:
                cfg = SchedulerConfig(num_workers=workers, fetch_size=fetch,
                                      persistent=True, max_rounds=1 << 20)
                t = timeit(lambda: bfs_speculative(g, 0, cfg)[0], iters=3)
                row(f"fig4/bfs/{dname}/w{workers}xf{fetch}", t * 1e6,
                    f"wavefront={workers * fetch}")


def run():
    run_figs123()
    run_fig4()
