"""Sharded-scheduler benchmark: rounds, exchange volume, occupancy balance.

  PYTHONPATH=src python -m benchmarks.run shard

Drains BFS (the exchange-heavy workload: improved neighbors are routed to
their owner every round) over the paper's two graph regimes at several
shard counts, via the discrete sharded driver so per-round telemetry is
observable.  Emits ``BENCH_shard.json`` with, per (graph, shard count):

  * rounds to drain (vs. the 1-shard run of the same machinery);
  * total + per-round task exchange volume (the all-to-all wire traffic,
    in tasks; the replica merge adds a fixed O(n)-per-round term recorded
    as ``merge_ints_per_round``);
  * per-device processed items and the min/max occupancy balance;
  * steal telemetry (donated tasks, triggered rounds) for the skewed
    single-source drain with stealing on vs. off;
  * the 2-D mesh sweep (DESIGN.md section 16, R-MAT): both 8-device
    layouts (2x4, 4x2) x strict/one-round-deferred delivery x raw/
    compressed wire — per-axis exchange volume, payload vs padding ints,
    metered wire ints (compressed strictly below the payload), and the
    overlap pipeline's occupancy;
  * autotune agreement: the cost-model-seeded successive-halving search
    reproduces the exhaustive grid's pick under the deterministic
    structural runner while measuring <= 1/4 of the cells.

The measurement itself runs in a subprocess that forces 8 XLA host devices
before jax initializes, so the benchmark works from any session (the parent
process may already hold a 1-device backend).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .harness import emit_json, row

OUT = "BENCH_shard.json"
SHARD_COUNTS = (1, 2, 4, 8)
SCALE = 8          # R-MAT: 2**8 vertices
GRID_SIDE = 16     # mesh: 16x16
#: 2-D mesh layouts (DESIGN.md section 16): both factorizations of the
#: 8-device pool, measured strict vs one-round-deferred, raw vs compressed
MESH_SHAPES = ((2, 4), (4, 2))
# launch shapes shared with benchmarks/smoke.py — the regression guard must
# recompute with exactly the configs that produced the checked-in JSON
SHARD_WORKERS = 32       # scaling sweep: per-device wavefront width
STEAL_WORKERS = 8        # steal case study: narrow wavefront, 8 shards
STEAL_THRESHOLD = 0.5
STEAL_CHUNK = 16


def _child() -> None:
    import time

    import numpy as np

    from repro.algorithms.bfs import bfs_bsp
    from repro.core import SchedulerConfig
    from repro.graph.generators import grid2d, rmat
    from repro import shard as SH
    from repro.runtime import build_program

    graphs = {
        "rmat": rmat(SCALE, edge_factor=8, seed=1),
        "grid": grid2d(GRID_SIDE, GRID_SIDE, seed=0),
    }
    payload: dict = {"shard_counts": list(SHARD_COUNTS), "graphs": {}}
    for name, g in graphs.items():
        ref = np.asarray(bfs_bsp(g, 0)[0])
        entry: dict = {"n": g.num_vertices, "m": g.num_edges, "shards": {}}
        for s in SHARD_COUNTS:
            cfg = SchedulerConfig(num_workers=SHARD_WORKERS, fetch_size=1,
                                  num_shards=s, persistent=False)
            program = build_program("bfs", g, cfg, params={"source": 0})
            trace: list = []
            t0 = time.perf_counter()
            state, stats = SH.run_sharded(program, g, cfg, trace=trace)
            wall = time.perf_counter() - t0
            assert (np.asarray(state.dist) == ref).all(), (name, s)
            assert stats.mis_routed == 0 and stats.dropped == 0
            entry["shards"][str(s)] = {
                "rounds": stats.rounds,
                "wall_seconds": wall,
                "exchanged_total": stats.exchanged,
                "per_round_exchanged": [t["exchanged"] for t in trace],
                "per_device_items": stats.per_device_items.tolist(),
                "occupancy_balance": stats.occupancy_balance,
                # every round merges the int32 dist replica via pmin
                "merge_ints_per_round": g.num_vertices,
            }
        # stealing case study: single-source drain seeds only shard 0 —
        # the most skewed start the partitioner can produce
        steal_cfgs = {
            "steal_off": SchedulerConfig(num_workers=STEAL_WORKERS,
                                         num_shards=8, persistent=False),
            "steal_on": SchedulerConfig(num_workers=STEAL_WORKERS,
                                        num_shards=8, persistent=False,
                                        steal_threshold=STEAL_THRESHOLD,
                                        steal_chunk=STEAL_CHUNK),
        }
        entry["steal"] = {}
        for label, cfg in steal_cfgs.items():
            program = build_program("bfs", g, cfg, params={"source": 0})
            state, stats = SH.run_sharded(program, g, cfg)
            assert (np.asarray(state.dist) == ref).all(), (name, label)
            entry["steal"][label] = {
                "rounds": stats.rounds,
                "donated": stats.donated,
                "steal_rounds": stats.steal_rounds,
                "stolen_executed": stats.stolen_executed,
                "occupancy_balance": stats.occupancy_balance,
            }
        # 2-D mesh sweep (section 16): both layouts x delivery mode x wire
        # codec, on the exchange-heavy R-MAT regime.  The per-axis and wire
        # meters are schedule-deterministic; walls are measurements.
        if name == "rmat":
            entry["mesh"] = {}
            for mesh in MESH_SHAPES:
                label = "%dx%d" % mesh
                entry["mesh"][label] = {}
                for dlabel, defer in (("strict", 0), ("defer", 1)):
                    cell = {}
                    for clabel, comp in (("raw", False),
                                         ("compressed", True)):
                        cfg = SchedulerConfig(num_workers=SHARD_WORKERS,
                                              num_shards=8, mesh_shape=mesh,
                                              defer_rounds=defer,
                                              compress=comp)
                        program = build_program("bfs", g, cfg,
                                                params={"source": 0})
                        t0 = time.perf_counter()
                        state, stats = SH.run_sharded(program, g, cfg)
                        wall = time.perf_counter() - t0
                        assert (np.asarray(state.dist) == ref).all(), \
                            (label, dlabel, clabel)
                        assert stats.mis_routed == 0 and stats.dropped == 0
                        if comp:
                            assert stats.wire_ints < stats.payload_ints, \
                                (label, dlabel, stats.wire_ints,
                                 stats.payload_ints)
                        cell[clabel] = {
                            "rounds": stats.rounds,
                            "wall_seconds": wall,
                            "exchanged_total": stats.exchanged,
                            "exchanged_row": stats.exchanged_row,
                            "exchanged_col": stats.exchanged_col,
                            "payload_ints": stats.payload_ints,
                            "padding_ints": stats.padding_ints,
                            "wire_ints": stats.wire_ints,
                            "deferred": stats.deferred_delivered,
                            "overlap_rounds": stats.overlap_rounds,
                            "overlap_occupancy": stats.overlap_occupancy,
                        }
                    entry["mesh"][label][dlabel] = cell

        # autotune agreement (section 16): the cost-model-seeded successive
        # halving must reproduce the exhaustive grid's pick on this
        # workload under the deterministic structural runner, measuring at
        # most a quarter of the cells.
        import tempfile
        from pathlib import Path

        from repro.server import Autotuner, structural_cost_runner

        with tempfile.TemporaryDirectory() as td:
            Autotuner(cache_path=Path(td) / "grid.json", warmup=0, iters=1,
                      runner=structural_cost_runner,
                      search="grid").tune("bfs", g)
            Autotuner(cache_path=Path(td) / "sh.json", warmup=0, iters=1,
                      runner=structural_cost_runner,
                      search="sh").tune("bfs", g)
            ge = next(iter(json.loads(
                (Path(td) / "grid.json").read_text()).values()))
            se = next(iter(json.loads(
                (Path(td) / "sh.json").read_text()).values()))
        entry["autotune"] = {
            "grid_chosen": ge["chosen"],
            "sh_chosen": se["chosen"],
            "agree": ge["chosen"] == se["chosen"],
            "cells_total": se["cells_total"],
            "cells_measured": se["cells_measured"],
        }
        assert entry["autotune"]["agree"], (name, ge["chosen"], se["chosen"])
        assert se["cells_measured"] <= se["cells_total"] // 4

        payload["graphs"][name] = entry
    print(json.dumps(payload))


def run(out: str = OUT):
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_shard", "--child"],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_shard child failed:\n{proc.stderr[-3000:]}")
    payload = json.loads(proc.stdout.strip().splitlines()[-1])

    for name, entry in payload["graphs"].items():
        base = entry["shards"]["1"]["rounds"]
        for s, m in sorted(entry["shards"].items(), key=lambda kv: int(kv[0])):
            row(f"shard/{name}/s{s}", m["wall_seconds"] * 1e6,
                f"rounds={m['rounds']} (1-shard={base}) "
                f"exchanged={m['exchanged_total']} "
                f"balance={m['occupancy_balance']:.3f}")
        on, off = entry["steal"]["steal_on"], entry["steal"]["steal_off"]
        row(f"shard/{name}/steal", 0.0,
            f"donated={on['donated']} steal_rounds={on['steal_rounds']} "
            f"balance {off['occupancy_balance']:.3f}->"
            f"{on['occupancy_balance']:.3f}")
        for label, modes in entry.get("mesh", {}).items():
            for dlabel, cell in modes.items():
                raw, comp = cell["raw"], cell["compressed"]
                row(f"shard/{name}/mesh{label}/{dlabel}",
                    comp["wall_seconds"] * 1e6,
                    f"rounds={comp['rounds']} "
                    f"row={comp['exchanged_row']} "
                    f"col={comp['exchanged_col']} "
                    f"wire {raw['wire_ints']}->{comp['wire_ints']} "
                    f"(payload={comp['payload_ints']}) "
                    f"overlap={comp['overlap_occupancy']:.2f}")
        if "autotune" in entry:
            at = entry["autotune"]
            row(f"shard/{name}/autotune", 0.0,
                f"agree={at['agree']} cells "
                f"{at['cells_measured']}/{at['cells_total']} "
                f"chosen={at['sh_chosen']}")
    emit_json(out, payload)
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run()
