"""Sharded-scheduler benchmark: rounds, exchange volume, occupancy balance.

  PYTHONPATH=src python -m benchmarks.run shard

Drains BFS (the exchange-heavy workload: improved neighbors are routed to
their owner every round) over the paper's two graph regimes at several
shard counts, via the discrete sharded driver so per-round telemetry is
observable.  Emits ``BENCH_shard.json`` with, per (graph, shard count):

  * rounds to drain (vs. the 1-shard run of the same machinery);
  * total + per-round task exchange volume (the all-to-all wire traffic,
    in tasks; the replica merge adds a fixed O(n)-per-round term recorded
    as ``merge_ints_per_round``);
  * per-device processed items and the min/max occupancy balance;
  * steal telemetry (donated tasks, triggered rounds) for the skewed
    single-source drain with stealing on vs. off.

The measurement itself runs in a subprocess that forces 8 XLA host devices
before jax initializes, so the benchmark works from any session (the parent
process may already hold a 1-device backend).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .harness import emit_json, row

OUT = "BENCH_shard.json"
SHARD_COUNTS = (1, 2, 4, 8)
SCALE = 8          # R-MAT: 2**8 vertices
GRID_SIDE = 16     # mesh: 16x16
# launch shapes shared with benchmarks/smoke.py — the regression guard must
# recompute with exactly the configs that produced the checked-in JSON
SHARD_WORKERS = 32       # scaling sweep: per-device wavefront width
STEAL_WORKERS = 8        # steal case study: narrow wavefront, 8 shards
STEAL_THRESHOLD = 0.5
STEAL_CHUNK = 16


def _child() -> None:
    import time

    import numpy as np

    from repro.algorithms.bfs import bfs_bsp
    from repro.core import SchedulerConfig
    from repro.graph.generators import grid2d, rmat
    from repro import shard as SH
    from repro.runtime import build_program

    graphs = {
        "rmat": rmat(SCALE, edge_factor=8, seed=1),
        "grid": grid2d(GRID_SIDE, GRID_SIDE, seed=0),
    }
    payload: dict = {"shard_counts": list(SHARD_COUNTS), "graphs": {}}
    for name, g in graphs.items():
        ref = np.asarray(bfs_bsp(g, 0)[0])
        entry: dict = {"n": g.num_vertices, "m": g.num_edges, "shards": {}}
        for s in SHARD_COUNTS:
            cfg = SchedulerConfig(num_workers=SHARD_WORKERS, fetch_size=1,
                                  num_shards=s, persistent=False)
            program = build_program("bfs", g, cfg, params={"source": 0})
            trace: list = []
            t0 = time.perf_counter()
            state, stats = SH.run_sharded(program, g, cfg, trace=trace)
            wall = time.perf_counter() - t0
            assert (np.asarray(state.dist) == ref).all(), (name, s)
            assert stats.mis_routed == 0 and stats.dropped == 0
            entry["shards"][str(s)] = {
                "rounds": stats.rounds,
                "wall_seconds": wall,
                "exchanged_total": stats.exchanged,
                "per_round_exchanged": [t["exchanged"] for t in trace],
                "per_device_items": stats.per_device_items.tolist(),
                "occupancy_balance": stats.occupancy_balance,
                # every round merges the int32 dist replica via pmin
                "merge_ints_per_round": g.num_vertices,
            }
        # stealing case study: single-source drain seeds only shard 0 —
        # the most skewed start the partitioner can produce
        steal_cfgs = {
            "steal_off": SchedulerConfig(num_workers=STEAL_WORKERS,
                                         num_shards=8, persistent=False),
            "steal_on": SchedulerConfig(num_workers=STEAL_WORKERS,
                                        num_shards=8, persistent=False,
                                        steal_threshold=STEAL_THRESHOLD,
                                        steal_chunk=STEAL_CHUNK),
        }
        entry["steal"] = {}
        for label, cfg in steal_cfgs.items():
            program = build_program("bfs", g, cfg, params={"source": 0})
            state, stats = SH.run_sharded(program, g, cfg)
            assert (np.asarray(state.dist) == ref).all(), (name, label)
            entry["steal"][label] = {
                "rounds": stats.rounds,
                "donated": stats.donated,
                "steal_rounds": stats.steal_rounds,
                "stolen_executed": stats.stolen_executed,
                "occupancy_balance": stats.occupancy_balance,
            }
        payload["graphs"][name] = entry
    print(json.dumps(payload))


def run(out: str = OUT):
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_shard", "--child"],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_shard child failed:\n{proc.stderr[-3000:]}")
    payload = json.loads(proc.stdout.strip().splitlines()[-1])

    for name, entry in payload["graphs"].items():
        base = entry["shards"]["1"]["rounds"]
        for s, m in sorted(entry["shards"].items(), key=lambda kv: int(kv[0])):
            row(f"shard/{name}/s{s}", m["wall_seconds"] * 1e6,
                f"rounds={m['rounds']} (1-shard={base}) "
                f"exchanged={m['exchanged_total']} "
                f"balance={m['occupancy_balance']:.3f}")
        on, off = entry["steal"]["steal_on"], entry["steal"]["steal_off"]
        row(f"shard/{name}/steal", 0.0,
            f"donated={on['donated']} steal_rounds={on['steal_rounds']} "
            f"balance {off['occupancy_balance']:.3f}->"
            f"{on['occupancy_balance']:.3f}")
    emit_json(out, payload)
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run()
