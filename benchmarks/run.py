"""Benchmark entry point — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [section ...]``
Sections: table1 table4 figs serving server kernels roofline shard
(default: all).  Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_figs, bench_kernels, bench_roofline, bench_server,
                   bench_serving, bench_shard, bench_table1, bench_table4)

    sections = {
        "table1": bench_table1.run,
        "table4": bench_table4.run,
        "figs": bench_figs.run,
        "serving": bench_serving.run,
        "server": bench_server.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
        "shard": bench_shard.run,
    }
    want = sys.argv[1:] or list(sections)
    print("name,us_per_call,derived")
    for name in want:
        sections[name]()


if __name__ == "__main__":
    main()
