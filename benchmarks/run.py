"""Benchmark entry point — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [section ...]``
Sections: table1 table4 figs serving server kernels roofline shard
granularity stream megakernel obs
(default: all).  Prints ``name,us_per_call,derived`` CSV.

``--smoke`` instead recomputes the schedule-deterministic counters (round
counts, exchange totals, donations) and exits non-zero if any disagrees
with the checked-in ``BENCH_*.json`` — the CI regression guard
(benchmarks/smoke.py).
"""
from __future__ import annotations

import sys


def main() -> None:
    argv = sys.argv[1:]
    if "--smoke" in argv:
        extra = [a for a in argv if a != "--smoke"]
        if extra:
            sys.exit(f"--smoke runs alone (got extra args {extra}); run "
                     f"sections first, then the smoke check")
        from . import smoke

        sys.exit(1 if smoke.run() else 0)

    from . import (bench_figs, bench_granularity, bench_kernels,
                   bench_megakernel, bench_obs, bench_roofline,
                   bench_server, bench_serving, bench_shard, bench_stream,
                   bench_table1, bench_table4)

    sections = {
        "table1": bench_table1.run,
        "table4": bench_table4.run,
        "figs": bench_figs.run,
        "serving": bench_serving.run,
        "server": bench_server.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
        "shard": bench_shard.run,
        "granularity": bench_granularity.run,
        "stream": bench_stream.run,
        "megakernel": bench_megakernel.run,
        "obs": bench_obs.run,
    }
    want = argv or list(sections)
    print("name,us_per_call,derived")
    for name in want:
        sections[name]()


if __name__ == "__main__":
    main()
