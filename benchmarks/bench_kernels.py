"""Kernel micro-benchmarks + backend comparison -> ``BENCH_kernels.json``.

  PYTHONPATH=src python -m benchmarks.run kernels

Two layers of measurement:

  * **kernel micro** — each Pallas kernel against its pure-jnp oracle
    (lbs / compact / flash), with an exact-agreement check so the numbers
    are only reported for matching outputs;
  * **backend dispatch** — the same comparison one level up, through the
    hot-path entry points the backend layer actually wires
    (``core.frontier.expand_merge_path`` and ``core.queue.TaskQueue.push``
    with ``backend="jnp"`` vs ``backend="pallas"``), which is what the
    autotuner's backend axis trades off (DESIGN.md section 9).

On CPU the Pallas side runs in interpret mode, so jnp winning is expected
and honest; on real TPU hardware the same harness times compiled Mosaic
kernels.  The JSON records wall time per side, the speedup, and the
agreement bit for every comparison.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .harness import emit_json, row, timeit

OUT = "BENCH_kernels.json"


def _compare(name: str, shape: str, jnp_fn, pallas_fn, agree: bool) -> dict:
    t_jnp = timeit(jnp_fn)
    t_pal = timeit(pallas_fn)
    row(f"kernels/{name}/jnp", t_jnp * 1e6, shape)
    row(f"kernels/{name}/pallas", t_pal * 1e6,
        f"{shape} agree={agree}")
    return {"shape": shape, "jnp_us": t_jnp * 1e6, "pallas_us": t_pal * 1e6,
            "pallas_over_jnp": t_pal / max(t_jnp, 1e-12), "agree": agree}


def run(out: str = OUT):
    from repro.core.backend import default_interpret, has_tpu

    rng = np.random.default_rng(0)
    results: dict = {}

    # ------------------------------------------------------ kernel micro
    from repro.kernels.frontier_expand.kernel import lbs_pallas
    from repro.kernels.frontier_expand.ref import lbs_ref
    deg = rng.integers(0, 32, size=1024).astype(np.int32)
    scan = jnp.cumsum(jnp.asarray(deg))
    agree = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(lbs_pallas(scan, 8192), lbs_ref(scan, 8192)))
    results["lbs"] = _compare(
        "lbs", "w=1024,budget=8192",
        lambda: lbs_ref(scan, 8192), lambda: lbs_pallas(scan, 8192), agree)

    from repro.kernels.queue_compact.ops import compact
    from repro.kernels.queue_compact.ref import compact_ref
    items = jnp.asarray(rng.integers(0, 1 << 20, size=4096), jnp.int32)
    mask = jnp.asarray(rng.random(4096) < 0.5)
    agree = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(compact(items, mask), compact_ref(items, mask)))
    results["compact"] = _compare(
        "compact", "n=4096",
        lambda: compact_ref(items, mask), lambda: compact(items, mask),
        agree)

    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.standard_normal((4, 256, 128)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 128)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 128)), jnp.float32)
    agree = bool(np.allclose(np.asarray(flash_attention_pallas(q, k, v)),
                             np.asarray(attention_ref(q, k, v)),
                             atol=2e-5, rtol=2e-5))
    results["flash"] = _compare(
        "flash", "bh4xs256xd128",
        lambda: attention_ref(q, k, v),
        lambda: flash_attention_pallas(q, k, v), agree)

    # ------------------------------------------- backend dispatch hot path
    # Both sides run under jax.jit, matching how the scheduler invokes them
    # (inside a compiled step) — timing eager jnp against jitted Pallas
    # wrappers would measure dispatch overhead, not backend cost.
    import functools

    import jax

    from repro.core import expand_merge_path, make_queue
    from repro.graph import rmat

    g = rmat(10, 8, seed=0)
    w = 256
    wave = jnp.asarray(rng.integers(0, g.num_vertices, size=w), jnp.int32)
    valid = jnp.ones((w,), bool)
    budget = 4 * w * max(1, g.num_edges // g.num_vertices)

    @functools.partial(jax.jit, static_argnames=("backend",))
    def _expand(wave, valid, backend):
        return expand_merge_path(wave, valid, g.row_ptr, g.col_idx, budget,
                                 backend=backend)

    agree = all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(_expand(wave, valid, "jnp"),
                                _expand(wave, valid, "pallas")))
    results["expand_merge_path"] = _compare(
        "expand_merge_path", f"wave={w},budget={budget}",
        lambda: _expand(wave, valid, "jnp"),
        lambda: _expand(wave, valid, "pallas"), agree)

    @functools.partial(jax.jit, static_argnames=("backend",))
    def _push(q, items, mask, backend):
        return q.push(items, mask, backend=backend)

    queue = make_queue(4 * w)
    pushed = jnp.asarray(rng.integers(0, 1 << 20, size=2 * w), jnp.int32)
    pmask = jnp.asarray(rng.random(2 * w) < 0.5)
    qa = _push(queue, pushed, pmask, "jnp")
    qb = _push(queue, pushed, pmask, "pallas")
    agree = all(
        np.array_equal(np.asarray(getattr(qa, f)), np.asarray(getattr(qb, f)))
        for f in ("buf", "head", "tail", "dropped"))
    results["queue_push"] = _compare(
        "queue_push", f"n={2 * w}",
        lambda: _push(queue, pushed, pmask, "jnp"),
        lambda: _push(queue, pushed, pmask, "pallas"), agree)

    payload = {
        "environment": {
            "tpu": has_tpu(),
            "pallas_interpret": default_interpret(),
            "note": ("interpret mode emulates the kernels off-TPU; jnp "
                     "winning there is expected — compare on TPU for the "
                     "compiled numbers"),
        },
        "comparisons": results,
    }
    emit_json(out, payload)
    return payload
