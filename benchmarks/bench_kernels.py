"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall time and
— more importantly on CPU — agreement sweeps.  On real TPU hardware the same
harness times the compiled kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .harness import row, timeit


def run():
    rng = np.random.default_rng(0)

    from repro.kernels.frontier_expand.kernel import lbs_pallas
    from repro.kernels.frontier_expand.ref import lbs_ref
    deg = rng.integers(0, 32, size=1024).astype(np.int32)
    scan = jnp.cumsum(jnp.asarray(deg))
    t_ref = timeit(lambda: lbs_ref(scan, 8192))
    t_pal = timeit(lambda: lbs_pallas(scan, 8192))
    row("kernels/lbs/ref", t_ref * 1e6, "budget=8192")
    row("kernels/lbs/pallas-interpret", t_pal * 1e6, "budget=8192")

    from repro.kernels.queue_compact.ops import compact
    from repro.kernels.queue_compact.ref import compact_ref
    items = jnp.asarray(rng.integers(0, 1 << 20, size=4096), jnp.int32)
    mask = jnp.asarray(rng.random(4096) < 0.5)
    t_ref = timeit(lambda: compact_ref(items, mask))
    t_pal = timeit(lambda: compact(items, mask))
    row("kernels/compact/ref", t_ref * 1e6, "n=4096")
    row("kernels/compact/pallas-interpret", t_pal * 1e6, "n=4096")

    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.standard_normal((4, 256, 128)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 128)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 128)), jnp.float32)
    t_ref = timeit(lambda: attention_ref(q, k, v))
    t_pal = timeit(lambda: flash_attention_pallas(q, k, v))
    row("kernels/flash/ref", t_ref * 1e6, "bh4xs256xd128")
    row("kernels/flash/pallas-interpret", t_pal * 1e6, "bh4xs256xd128")
