"""Megakernel benchmark: launches-per-drain collapse + roofline posture.

  PYTHONPATH=src python -m benchmarks.run megakernel

Runs the three algorithms over the same R-MAT graph under the three kernel
strategies of the single topology — ``persistent`` (device while-loop,
one kernel entry per round), ``discrete`` (host loop, one dispatch per
round) and ``megakernel`` (the whole drain fused into ONE Pallas launch,
kernels/drain_loop, DESIGN.md section 14) — and emits
``BENCH_megakernel.json`` with, per (algorithm x kernel), the
schedule-deterministic rounds / launches / work counters plus wall
seconds.  The headline ``findings`` block pins the subsystem's reason to
exist as data: **kernel-entry events per drain collapse from O(rounds)
to exactly 1** while every result stays bit-identical to the persistent
drain (the megakernel body IS the persistent while-loop's jaxpr,
evaluated in-kernel).

The ``roofline`` section compiles the persistent drain body once
(``launch/roofline.cost_terms``), composes the per-round HLO bytes/flops
over the measured round count (XLA costs a while-loop body once, the same
convention launch/dryrun.py uses for scans), then ADDS the megakernel's
own streamed-slice traffic — every expansion DMAs ``wavefront x
work_budget`` int32 lanes regardless of actual chunk degrees
(``kernels/drain_loop/csr_stream``, DESIGN.md section 14) — and reports
the memory/compute terms against the TPU v5e roofline next to the
measured megakernel wall — achieved-vs-roofline bandwidth.  Wall-based
numbers are excluded from the CI guard like every other timing; the
rounds / launches / work counters are recomputed by
``benchmarks/smoke.py`` on every push.

The megakernel is an interpret-mode prototype (no Mosaic lowering for the
jaxpr-in-kernel body yet, DESIGN.md section 14), so its wall seconds are
an emulation artifact on every backend — the counters and the parity bit
are the portable signal, and the roofline terms bound what a future
compiled lowering would have to beat.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .harness import emit_json, row

OUT = "BENCH_megakernel.json"
# shared with benchmarks/smoke.py — the regression guard recomputes with
# exactly the configs that produced the checked-in JSON
SCALE = 7           # R-MAT: 2**7 vertices
EDGE_FACTOR = 8
GRAPH_SEED = 1
WORKERS = 32
PR_EPS = 1e-4
KERNELS = ("persistent", "discrete", "megakernel")
ALGOS = (("bfs", {"source": 0}), ("pagerank", {"eps": PR_EPS}),
         ("coloring", {}))


def _child() -> None:
    import time

    import jax
    import numpy as np

    from repro.core import SchedulerConfig
    from repro.graph.generators import rmat
    from repro.launch.roofline import (HBM_BW, cost_terms, make_roofline)
    from repro.runtime import (ExecutionPolicy, build_program, config_for,
                               execute)

    g = rmat(SCALE, edge_factor=EDGE_FACTOR, seed=GRAPH_SEED)
    payload: dict = {
        "config": {"scale": SCALE, "edge_factor": EDGE_FACTOR,
                   "workers": WORKERS, "eps": PR_EPS},
        "algorithms": {},
    }

    for algo, params in ALGOS:
        entry: dict = {}
        results = {}
        for kernel in KERNELS:
            cfg = config_for(SchedulerConfig(num_workers=WORKERS),
                             ExecutionPolicy("single", kernel))
            program = build_program(algo, g, cfg, params=dict(params))
            t0 = time.perf_counter()
            state, stats, info = execute(program, g, cfg)
            wall = time.perf_counter() - t0
            assert info["dropped"] == 0, (algo, kernel)
            results[kernel] = np.asarray(program.result(state))
            entry[kernel] = {
                "rounds": info["rounds"],
                "launches": info["launches"],
                "work": info["work"],
                "wall_seconds": wall,
            }
        # the whole point, asserted at measurement time: one launch per
        # drain, bit-identical state
        assert entry["megakernel"]["launches"] == 1, algo
        assert entry["persistent"]["launches"] == \
            entry["persistent"]["rounds"], algo
        assert (results["megakernel"] == results["persistent"]).all(), algo
        entry["parity_vs_persistent"] = True
        payload["algorithms"][algo] = entry

    # roofline: compile the persistent BFS drain, cost its body once, and
    # compose the per-round HLO terms over the measured round count
    from repro.runtime.api import _shared_setup
    from repro.runtime.policy import policy_of
    import jax.numpy as jnp

    cfg = config_for(SchedulerConfig(num_workers=WORKERS),
                     ExecutionPolicy("single", "persistent"))
    program = build_program("bfs", g, cfg, params={"source": 0})
    queue, state, ops, step, cond, _ = _shared_setup(
        program, g, cfg, policy_of(cfg), None)
    carry0 = (queue, state, jnp.int32(0), jnp.int32(0))
    drain = jax.jit(lambda c: jax.lax.while_loop(cond, step, c))
    compiled = drain.lower(carry0).compile()
    per_round = cost_terms(compiled)
    rounds = payload["algorithms"]["bfs"]["persistent"]["rounds"]
    total = per_round.scaled(float(rounds))
    roof = make_roofline(total, chips=1, model_flops=total.flops)
    mega_wall = payload["algorithms"]["bfs"]["megakernel"]["wall_seconds"]
    # the megakernel's streamed-slice term: every expansion DMAs a full
    # wavefront x work_budget int32 block regardless of chunk degrees
    # (csr_stream, DESIGN.md section 14) — traffic the persistent drain's
    # HLO byte count does not model, so it is added explicitly before
    # computing achieved bandwidth.
    from repro.algorithms.common import default_work_budget
    work_budget = default_work_budget(g, cfg.wavefront)
    stream_bytes = float(rounds) * cfg.wavefront * work_budget * 4
    mega_bytes = total.bytes + stream_bytes
    achieved_bw = mega_bytes / mega_wall if mega_wall else 0.0
    payload["roofline"] = {
        "drain": "bfs/persistent body x rounds + megakernel stream term",
        "rounds": rounds,
        "hlo_flops": total.flops,
        "hlo_bytes": total.bytes,
        "stream_slice_bytes": stream_bytes,
        "megakernel_bytes": mega_bytes,
        "t_compute_s": roof.t_compute,
        "t_memory_s": roof.t_memory,
        "dominant": roof.dominant,
        "megakernel_wall_seconds": mega_wall,
        "achieved_bytes_per_s": achieved_bw,
        "roofline_bw_fraction": achieved_bw / HBM_BW,
        "backend": jax.default_backend(),
    }

    payload["findings"] = {
        "launch_collapse": {
            a: {"persistent": payload["algorithms"][a]["persistent"]
                ["launches"],
                "megakernel": payload["algorithms"][a]["megakernel"]
                ["launches"]}
            for a, _ in ALGOS},
        "bit_identical_to_persistent": {
            a: payload["algorithms"][a]["parity_vs_persistent"]
            for a, _ in ALGOS},
    }
    print(json.dumps(payload))


def run(out: str = OUT):
    env = dict(os.environ,
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_megakernel", "--child"],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_megakernel child failed:\n{proc.stderr[-3000:]}")
    payload = json.loads(proc.stdout.strip().splitlines()[-1])

    for algo, entry in payload["algorithms"].items():
        for kernel in KERNELS:
            cell = entry[kernel]
            row(f"megakernel/{algo}/{kernel}", cell["wall_seconds"] * 1e6,
                f"rounds={cell['rounds']} launches={cell['launches']} "
                f"work={cell['work']}")
    r = payload["roofline"]
    row("megakernel/roofline", r["megakernel_wall_seconds"] * 1e6,
        f"dom={r['dominant']} tC={r['t_compute_s']:.2e} "
        f"tM={r['t_memory_s']:.2e} "
        f"bw_frac={r['roofline_bw_fraction']:.2e} "
        f"backend={r['backend']}")
    emit_json(out, payload)
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run()
