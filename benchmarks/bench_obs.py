"""Observability benchmark: tracing overhead + trace artifact emission.

  PYTHONPATH=src python -m benchmarks.run obs

Runs BFS over the bench R-MAT graph twice per policy cell — once
untraced, once with a ``repro.obs.Trace`` ring threaded through the
drain (DESIGN.md section 15) — and emits ``BENCH_obs.json`` with, per
cell, the parity bit (traced results/stats bit-identical to untraced —
the ring rides the carry but never feeds back into scheduling), the ring
record count (one row per round, zero host syncs while tracing) and the
traced/untraced wall ratio against the issue's <=10% overhead budget.
Wall-based numbers are excluded from the CI guard like every other
timing — the parity bits and record counts are the schedule-
deterministic signal ``benchmarks/smoke.py`` recomputes on every push.

The traced BFS run's artifacts are emitted alongside the JSON:
``BENCH_obs_trace.json`` (Perfetto-loadable Chrome trace of every round)
and ``BENCH_obs_metrics.jsonl`` (canonical metrics docs: meta, run
summary, spans, per-round records), both validated against
``repro/obs/schema.py`` at emission time and again by the smoke guard.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .harness import bench_meta, emit_json, row

OUT = "BENCH_obs.json"
TRACE_OUT = "BENCH_obs_trace.json"
METRICS_OUT = "BENCH_obs_metrics.jsonl"
# shared with benchmarks/smoke.py — the regression guard recomputes with
# exactly the configs that produced the checked-in JSON
SCALE = 7           # R-MAT: 2**7 vertices
EDGE_FACTOR = 8
GRAPH_SEED = 1
WORKERS = 32
OVERHEAD_BUDGET = 1.10     # issue acceptance: <=10% on the smoke workload
CELLS = ("single.persistent", "single.discrete", "fused.persistent",
         "single.persistent.g4")


def _child() -> None:
    import time

    import numpy as np

    from repro.core import SchedulerConfig
    from repro.graph.generators import rmat
    from repro.obs import (Trace, validate_chrome_trace,
                           validate_metrics_jsonl)
    from repro.runtime import build_program, config_for, execute, parse_policy

    g = rmat(SCALE, edge_factor=EDGE_FACTOR, seed=GRAPH_SEED)
    payload: dict = {
        "config": {"scale": SCALE, "edge_factor": EDGE_FACTOR,
                   "workers": WORKERS, "overhead_budget": OVERHEAD_BUDGET},
        "cells": {},
    }

    def wall_of(fn, iters=5):
        fn()                       # warmup (compile)
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        # min, not median: both paths retrace per call on this workload, so
        # the floor is the honest per-call cost and the overhead ratio is
        # least noise-sensitive there
        return min(times)

    keep_trace = None
    for cell in CELLS:
        policy = parse_policy(cell)
        cfg = config_for(SchedulerConfig(num_workers=WORKERS), policy)
        program = build_program("bfs", g, cfg, params={"source": 0})

        base_state, base_stats, base_info = execute(program, g, cfg)
        trace = Trace()
        tr_state, tr_stats, tr_info = execute(program, g, cfg, trace=trace)

        parity = bool(
            (np.asarray(program.result(tr_state))
             == np.asarray(program.result(base_state))).all()
            and tr_info == base_info)
        wall_off = wall_of(lambda: execute(program, g, cfg))
        wall_on = wall_of(
            lambda: execute(program, g, cfg, trace=Trace()))
        ratio = wall_on / wall_off if wall_off else 1.0
        payload["cells"][cell] = {
            "rounds": base_info["rounds"],
            "work": base_info["work"],
            "ring_records": len(trace.records),
            "parity": parity,
            "wall_off_seconds": wall_off,
            "wall_on_seconds": wall_on,
            "overhead_ratio": ratio,
            "within_budget": ratio <= OVERHEAD_BUDGET,
        }
        if cell == "single.persistent":
            keep_trace = trace

    # emit + validate the traced run's artifacts (the acceptance bullet:
    # traced BFS on the bench R-MAT emits a Perfetto-loadable trace and
    # a schema-valid metrics JSONL)
    keep_trace.meta.update(
        {k: v for k, v in json.loads(sys.argv[-1]).items()
         if k != "schema"})
    keep_trace.write(TRACE_OUT, METRICS_OUT)
    with open(TRACE_OUT) as f:
        events = validate_chrome_trace(json.load(f))
    with open(METRICS_OUT) as f:
        docs = validate_metrics_jsonl(f.read().splitlines())

    payload["artifacts"] = {
        "trace": TRACE_OUT, "trace_events": events,
        "metrics": METRICS_OUT, "metrics_docs": docs,
    }
    payload["findings"] = {
        "tracing_disabled_is_identity": all(
            c["parity"] for c in payload["cells"].values()),
        "one_record_per_round": all(
            c["ring_records"] == c["rounds"]
            for c in payload["cells"].values()),
        "overhead_within_budget": all(
            c["within_budget"] for c in payload["cells"].values()),
    }
    print(json.dumps(payload))


def run(out: str = OUT):
    env = dict(os.environ,
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_obs", "--child",
         json.dumps(bench_meta())],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_obs child failed:\n{proc.stderr[-3000:]}")
    payload = json.loads(proc.stdout.strip().splitlines()[-1])

    for cell, c in payload["cells"].items():
        row(f"obs/{cell}", c["wall_on_seconds"] * 1e6,
            f"rounds={c['rounds']} records={c['ring_records']} "
            f"parity={c['parity']} "
            f"overhead={c['overhead_ratio']:.3f}x")
    a = payload["artifacts"]
    row("obs/artifacts", 0.0,
        f"trace_events={a['trace_events']} metrics_docs={a['metrics_docs']}")
    emit_json(out, payload)
    return payload


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run()
